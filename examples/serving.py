"""Batched serving example: prefill + decode with the fixed-capacity donated
KV cache, streaming live-memory per request — demonstrating that serving
memory is flat (the framework-level fix for the paper's App-B generate()
pathology).

With ``--backend paged`` the same traffic runs through the paged KV cache
(`repro.paged`): a continuous batcher admits ragged-length requests
against a global page pool and the example prints reserved-KV pages as
the pool breathes — the vLLM-style layout where reserved memory tracks
live tokens instead of worst-case capacity.

    PYTHONPATH=src python examples/serving.py [--arch mamba2_370m]
    PYTHONPATH=src python examples/serving.py --backend paged
"""
import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, "src")

from repro.configs import get_config
from repro.data import ByteTokenizer, PromptDataset, \
    synthetic_instruction_prompts
from repro.models import Model
from repro.rlhf import Rollout, live_device_bytes


def paged_demo(args):
    from repro.serving import ContinuousBatcher
    cfg = get_config(args.arch).smoke()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    capacity = 24 + args.gen
    cb = ContinuousBatcher(model, cfg, params, slots=args.batch,
                           capacity=capacity, temperature=0.8, top_k=40,
                           cache_backend="paged", page_size=16)
    rng = np.random.RandomState(0)
    n_req = args.batch * args.requests
    for i in range(n_req):
        # ragged: every request decodes a different number of tokens
        cb.submit(rng.randint(0, cfg.vocab_size, size=24),
                  int(rng.randint(args.gen // 4, args.gen)))
    print(f"serving {cfg.name} [paged] | pool {cb.pm.num_pages} pages "
          f"x {cb.pm.page_size} tokens")
    done, t0 = 0, time.time()
    while done < n_req:
        done += len(cb.step())
        if cb.steps % 8 == 0 or done == n_req:
            st = cb.pm.stats
            print(f"step {cb.steps:4d}: done {done:3d}/{n_req}  "
                  f"pages {st.pages_in_use:3d}/{st.num_pages}  "
                  f"reserved {cb.pm.reserved_bytes()/2**20:6.2f} MiB  "
                  f"frag {cb.pm.fragmentation_slots():3d} slots")
    dense_bytes = cb.B * capacity * (cb.pm.bytes_per_token or 1)
    print(f"drained in {time.time()-t0:.1f}s | peak "
          f"{st.peak_pages_in_use * cb.pm.page_bytes / 2**20:.2f} MiB paged "
          f"vs {dense_bytes/2**20:.2f} MiB dense [B, capacity]")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3_2_3b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--gen", type=int, default=48)
    ap.add_argument("--requests", type=int, default=5)
    ap.add_argument("--backend", default="dense",
                    choices=("dense", "paged"))
    args = ap.parse_args()
    if args.backend == "paged":
        paged_demo(args)
        return

    cfg = get_config(args.arch).smoke()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tok = ByteTokenizer()
    prompt_len = 24
    ro = Rollout(model, cfg, capacity=prompt_len + args.gen,
                 temperature=0.8, top_k=40)
    ds = PromptDataset(
        synthetic_instruction_prompts(args.batch * args.requests),
        prompt_len)
    it = ds.batches(args.batch)
    key = jax.random.PRNGKey(1)
    print(f"serving {cfg.name} | live {live_device_bytes()/2**20:.1f} MiB")
    for r in range(args.requests):
        key, k = jax.random.split(key)
        batch = jnp.asarray(next(it)) % cfg.vocab_size
        t0 = time.time()
        res = ro.generate(params, {"tokens": batch}, args.gen, k)
        dt = time.time() - t0
        print(f"req {r}: {dt*1e3:7.1f} ms  "
              f"{args.batch*args.gen/dt:7.0f} tok/s  "
              f"live {live_device_bytes()/2**20:7.1f} MiB")
        del res


if __name__ == "__main__":
    main()
