"""The paper's memory study through the public core API: build the RLHF
phase traces for the OPT workload, replay them through the caching-allocator
simulator under a chosen strategy, and compare empty_cache policies — with
an optional runtime-offload axis (``--offload``, ``--engine hydra``) that
parks off-phase role state to host at phase boundaries.

    PYTHONPATH=src python examples/memory_study.py [--strategy ZeRO-3]
    PYTHONPATH=src python examples/memory_study.py --engine hydra --offload all
    PYTHONPATH=src python examples/memory_study.py --ndp 8 --zero-stage 3

The ``--ndp``/``--zero-stage`` axis is traced from the real sharded spec
trees (``core.strategies.traced_strategy``), not the closed-form ``1/ndp``.
"""
import argparse
import dataclasses
import sys

sys.path.insert(0, "src")

from repro.configs import get_config
from repro.core import (OFFLOAD_LEVELS, PAPER_STRATEGIES, build_rlhf_phases,
                        lora_trainable_fraction, run_iteration,
                        traced_strategy)

GB = 1 << 30


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--strategy", default="All Enabled",
                    choices=[s.name for s in PAPER_STRATEGIES])
    ap.add_argument("--gen-lens", type=int, nargs="*",
                    default=[180, 256, 199, 243])
    ap.add_argument("--engine", default="separate",
                    choices=("separate", "hydra"))
    ap.add_argument("--offload", default="none", choices=OFFLOAD_LEVELS,
                    help="runtime host-offload level applied at phase "
                         "boundaries (repro.offload)")
    ap.add_argument("--ndp", type=int, default=4,
                    help="DP/ZeRO domain size of the simulated node")
    ap.add_argument("--zero-stage", type=int, default=None,
                    choices=(0, 1, 2, 3),
                    help="override the strategy's ZeRO stage; with --ndp "
                         "the per-device fractions are TRACED from the "
                         "real sharded spec trees, not the closed-form "
                         "1/ndp (core.strategies.traced_strategy)")
    args = ap.parse_args()
    strat = {s.name: s for s in PAPER_STRATEGIES}[args.strategy]
    strat = dataclasses.replace(strat, offload=args.offload)
    if args.zero_stage is not None:
        strat = dataclasses.replace(strat, zero_stage=args.zero_stage)

    actor, critic = get_config("opt_1_3b"), get_config("opt_350m")
    # hydra phase plans carry exact adapter-sized opt/grad buffers already
    tf = 1.0 if args.engine == "hydra" else lora_trainable_fraction(actor, 128)
    print(f"building phase traces (grad_ckpt={strat.grad_ckpt}, "
          f"engine={args.engine}) ...")
    plans, persist = [], None
    for gl in args.gen_lens:
        ph, persist = build_rlhf_phases(actor, critic, gen_len=gl,
                                        naive_generation=True,
                                        grad_ckpt=strat.grad_ckpt,
                                        engine=args.engine)
        plans.append(ph)
    # trace the ndp axis from the real sharded spec trees (value heads,
    # norms etc. that cannot shard are charged at full size)
    strat = traced_strategy(strat, actor, critic, ndp=args.ndp,
                            engine=args.engine)

    print(f"\nstrategy: {strat.name}  (DP={args.ndp}, "
          f"zero_stage={strat.zero_stage}, LoRA-128, 24 GB device, "
          f"offload={args.offload})")
    print(f"{'policy':16s} {'reserved':>9s} {'frag@peak':>10s} "
          f"{'allocated':>10s} {'time':>8s}")
    base = None
    for policy in ("none", "after_inference", "after_training", "after_all"):
        r = run_iteration(plans, persist, strat, policy, ndp=args.ndp,
                          trainable_fraction=tf)
        if policy == "none":
            base = r
        host = f" (host {r.peak_host_bytes/GB:.2f}G)" \
            if r.peak_host_bytes else ""
        print(f"{policy:16s} {r.peak_reserved/GB:8.2f}G "
              f"{r.frag_at_peak/GB:9.2f}G {r.peak_allocated/GB:9.2f}G "
              f"{r.time_s:7.2f}s{host}")
    fixed = run_iteration(plans, persist, strat, "after_inference", ndp=args.ndp,
                          trainable_fraction=tf)
    print(f"\nempty_cache after inference: "
          f"-{100*(1-fixed.peak_reserved/base.peak_reserved):.0f}% memory, "
          f"+{100*(fixed.time_s/base.time_s-1):.1f}% time "
          f"(paper: -25%, +2%)")


if __name__ == "__main__":
    main()
