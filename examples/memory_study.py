"""The paper's memory study through the public core API: build the RLHF
phase traces for the OPT workload, replay them through the caching-allocator
simulator under a chosen strategy, and compare empty_cache policies — with
an optional runtime-offload axis (``--offload``, ``--engine hydra``) that
parks off-phase role state to host at phase boundaries.

    PYTHONPATH=src python examples/memory_study.py [--strategy ZeRO-3]
    PYTHONPATH=src python examples/memory_study.py --engine hydra --offload all
"""
import argparse
import dataclasses
import sys

sys.path.insert(0, "src")

from repro.configs import get_config
from repro.core import (OFFLOAD_LEVELS, PAPER_STRATEGIES, build_rlhf_phases,
                        lora_trainable_fraction, run_iteration)

GB = 1 << 30


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--strategy", default="All Enabled",
                    choices=[s.name for s in PAPER_STRATEGIES])
    ap.add_argument("--gen-lens", type=int, nargs="*",
                    default=[180, 256, 199, 243])
    ap.add_argument("--engine", default="separate",
                    choices=("separate", "hydra"))
    ap.add_argument("--offload", default="none", choices=OFFLOAD_LEVELS,
                    help="runtime host-offload level applied at phase "
                         "boundaries (repro.offload)")
    args = ap.parse_args()
    strat = {s.name: s for s in PAPER_STRATEGIES}[args.strategy]
    strat = dataclasses.replace(strat, offload=args.offload)

    actor, critic = get_config("opt_1_3b"), get_config("opt_350m")
    # hydra phase plans carry exact adapter-sized opt/grad buffers already
    tf = 1.0 if args.engine == "hydra" else lora_trainable_fraction(actor, 128)
    print(f"building phase traces (grad_ckpt={strat.grad_ckpt}, "
          f"engine={args.engine}) ...")
    plans, persist = [], None
    for gl in args.gen_lens:
        ph, persist = build_rlhf_phases(actor, critic, gen_len=gl,
                                        naive_generation=True,
                                        grad_ckpt=strat.grad_ckpt,
                                        engine=args.engine)
        plans.append(ph)

    print(f"\nstrategy: {strat.name}  (DP=4, LoRA-128, 24 GB device, "
          f"offload={args.offload})")
    print(f"{'policy':16s} {'reserved':>9s} {'frag@peak':>10s} "
          f"{'allocated':>10s} {'time':>8s}")
    base = None
    for policy in ("none", "after_inference", "after_training", "after_all"):
        r = run_iteration(plans, persist, strat, policy, ndp=4,
                          trainable_fraction=tf)
        if policy == "none":
            base = r
        host = f" (host {r.peak_host_bytes/GB:.2f}G)" \
            if r.peak_host_bytes else ""
        print(f"{policy:16s} {r.peak_reserved/GB:8.2f}G "
              f"{r.frag_at_peak/GB:9.2f}G {r.peak_allocated/GB:9.2f}G "
              f"{r.time_s:7.2f}s{host}")
    fixed = run_iteration(plans, persist, strat, "after_inference", ndp=4,
                          trainable_fraction=tf)
    print(f"\nempty_cache after inference: "
          f"-{100*(1-fixed.peak_reserved/base.peak_reserved):.0f}% memory, "
          f"+{100*(fixed.time_s/base.time_s-1):.1f}% time "
          f"(paper: -25%, +2%)")


if __name__ == "__main__":
    main()
