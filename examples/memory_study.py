"""The paper's memory study through the public core API: build the RLHF
phase traces for the OPT workload, replay them through the caching-allocator
simulator under a chosen strategy, and compare empty_cache policies.

    PYTHONPATH=src python examples/memory_study.py [--strategy ZeRO-3]
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.configs import get_config
from repro.core import (PAPER_STRATEGIES, build_rlhf_phases,
                        lora_trainable_fraction, run_iteration)

GB = 1 << 30


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--strategy", default="All Enabled",
                    choices=[s.name for s in PAPER_STRATEGIES])
    ap.add_argument("--gen-lens", type=int, nargs="*",
                    default=[180, 256, 199, 243])
    args = ap.parse_args()
    strat = {s.name: s for s in PAPER_STRATEGIES}[args.strategy]

    actor, critic = get_config("opt_1_3b"), get_config("opt_350m")
    tf = lora_trainable_fraction(actor, 128)
    print(f"building phase traces (grad_ckpt={strat.grad_ckpt}) ...")
    plans, persist = [], None
    for gl in args.gen_lens:
        ph, persist = build_rlhf_phases(actor, critic, gen_len=gl,
                                        naive_generation=True,
                                        grad_ckpt=strat.grad_ckpt)
        plans.append(ph)

    print(f"\nstrategy: {strat.name}  (DP=4, LoRA-128, 24 GB device)")
    print(f"{'policy':16s} {'reserved':>9s} {'frag@peak':>10s} "
          f"{'allocated':>10s} {'time':>8s}")
    base = None
    for policy in ("none", "after_inference", "after_training", "after_all"):
        r = run_iteration(plans, persist, strat, policy, ndp=4,
                          trainable_fraction=tf)
        if policy == "none":
            base = r
        print(f"{policy:16s} {r.peak_reserved/GB:8.2f}G "
              f"{r.frag_at_peak/GB:9.2f}G {r.peak_allocated/GB:9.2f}G "
              f"{r.time_s:7.2f}s")
    fixed = run_iteration(plans, persist, strat, "after_inference", ndp=4,
                          trainable_fraction=tf)
    print(f"\nempty_cache after inference: "
          f"-{100*(1-fixed.peak_reserved/base.peak_reserved):.0f}% memory, "
          f"+{100*(fixed.time_s/base.time_s-1):.1f}% time "
          f"(paper: -25%, +2%)")


if __name__ == "__main__":
    main()
