"""End-to-end RLHF driver: PPO over the four-model setup (actor, critic,
reference, reward) with a verifiable programmatic reward, phase-boundary
memory management (the paper's technique), and checkpointing.

Default scale is CPU-friendly (~6M-param actor, 120 PPO iterations — reward
climbs from the 1/64 random baseline to >0.5). Scale up with the flags.

    PYTHONPATH=src python examples/rlhf_e2e.py [--steps 120] [--d-model 128]
"""
import argparse
import dataclasses
import sys
import time

import jax
import jax.numpy as jnp

sys.path.insert(0, "src")

from repro.checkpoint import save
from repro.configs import get_config
from repro.rlhf import RLHFConfig, RLHFTrainer
from repro.rlhf.reward import make_target_token_reward


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--memory-policy", default="after_inference",
                    choices=("none", "after_inference", "after_all"))
    ap.add_argument("--ckpt-dir", default="")
    args = ap.parse_args()

    cfg = dataclasses.replace(
        get_config("llama3_2_3b").smoke(), num_layers=args.layers,
        d_model=args.d_model, d_ff=2 * args.d_model, vocab_size=64,
        num_heads=4, num_kv_heads=2, head_dim=args.d_model // 4)
    rl = RLHFConfig(prompt_len=8, gen_len=16, lr=3e-3, critic_lr=3e-3,
                    kl_coef=0.0, top_k=0,
                    memory_policy=args.memory_policy)
    trainer = RLHFTrainer(cfg, cfg, rl, jax.random.PRNGKey(0),
                          reward_fn=make_target_token_reward(7))

    key = jax.random.PRNGKey(1)
    t0 = time.time()
    for step in range(args.steps):
        k1, k2, key = jax.random.split(key, 3)
        prompts = jax.random.randint(k1, (args.batch, rl.prompt_len), 0,
                                     cfg.vocab_size)
        m = trainer.train_step(prompts, k2)
        if step % 10 == 0 or step == args.steps - 1:
            print(f"step {step:4d} reward {m['mean_reward']:+.4f} "
                  f"kl {m['kl']:.4f} clip {m['clip_frac']:.3f} "
                  f"vf {m['vf_loss']:.4f} ({time.time()-t0:.0f}s)")

    # per-phase live-memory report (the paper's profiler, on the real run)
    recs = trainer.memory.records[-7:]
    print("\nlast-iteration phase memory (policy="
          f"{args.memory_policy}):")
    for r in recs:
        print(f"  {r['phase']:16s} {r['kind']:10s} "
              f"{r['live_bytes']/2**20:8.2f} MiB live")
    if args.ckpt_dir:
        print("saved:", save(args.ckpt_dir, args.steps,
                             trainer.actor_state["params"]))


if __name__ == "__main__":
    main()
