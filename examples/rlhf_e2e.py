"""End-to-end RLHF driver, default on the shared-base "hydra" engine: ONE
frozen trunk + per-role LoRA adapters/value heads (actor, critic, reward)
with the reference logp read straight off the base — versus the four-model
pipeline (``--engine separate``) it replaces. Verifiable programmatic
reward, phase-boundary memory management (the paper's technique), and
checkpointing.

Default scale is CPU-friendly (~6M-param trunk, 120 PPO iterations — reward
climbs from the 1/64 random baseline to >0.5). Scale up with the flags.

    PYTHONPATH=src python examples/rlhf_e2e.py [--steps 120] [--d-model 128]
    PYTHONPATH=src python examples/rlhf_e2e.py --engine separate   # A/B
"""
import argparse
import dataclasses
import sys
import time

import jax
import jax.numpy as jnp

sys.path.insert(0, "src")

from repro.checkpoint import save
from repro.configs import get_config
from repro.rlhf import RLHFConfig, RLHFTrainer, live_device_bytes
from repro.rlhf.reward import make_target_token_reward


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--engine", default="hydra",
                    choices=("hydra", "separate"))
    ap.add_argument("--lora-rank", type=int, default=16,
                    help="hydra adapter rank (the paper grid uses 128)")
    ap.add_argument("--memory-policy", default="after_inference",
                    choices=("none", "after_inference", "after_training",
                             "after_all"))
    ap.add_argument("--offload", default="none",
                    choices=("none", "optimizer", "roles", "all"),
                    help="runtime host-offload level (repro.offload): park "
                         "off-phase role state to host between the PPO "
                         "phases that touch it")
    ap.add_argument("--ndp", type=int, default=1,
                    help="DP/ZeRO domain size: shard params/opt over this "
                         "many devices (needs >= ndp local devices, e.g. "
                         "XLA_FLAGS=--xla_force_host_platform_device_count=8)")
    ap.add_argument("--zero-stage", type=int, default=3,
                    choices=(0, 1, 2, 3),
                    help="ZeRO stage for --ndp > 1 (DESIGN.md §2)")
    ap.add_argument("--ntp", type=int, default=1,
                    help="tensor-parallel degree: Megatron column/row "
                         "sharding over a (data=ndp, model=ntp) mesh, "
                         "composed with the ZeRO stage (DESIGN.md §9); "
                         "needs ndp*ntp local devices")
    ap.add_argument("--lr", type=float, default=0.0,
                    help="0 = engine default (adapters train at ~10x the "
                         "full-finetune rate: LoRA's B=0 init scales the "
                         "effective step down)")
    ap.add_argument("--spec-decode", action="store_true",
                    help="MTP self-speculative greedy rollout (bit-identical "
                         "to vanilla greedy; forces temperature=0, top_k=0 "
                         "and gives the actor an MTP head)")
    ap.add_argument("--spec-k", type=int, default=2,
                    help="draft tokens per speculative step")
    ap.add_argument("--capture-buckets", default="",
                    help="comma list of prefill compile-bucket sizes, "
                         "e.g. 8,16,32")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--metrics-out", default="", metavar="PATH",
                    help="write the run's telemetry JSONL (spans + metrics; "
                         "render with python -m repro.launch.report)")
    ap.add_argument("--trace-out", default="", metavar="PATH",
                    help="write a Chrome-trace JSON (Perfetto-loadable)")
    ap.add_argument("--watermark", type=float, default=0.0,
                    metavar="FRACTION",
                    help="arm the OOM flight recorder: dump a forensic "
                         "owner/buffer bundle when live bytes cross this "
                         "fraction of capacity (or on RESOURCE_EXHAUSTED); "
                         "0 disables")
    ap.add_argument("--attrib-out", default="", metavar="PATH",
                    help="write the per-phase owner attribution tables + "
                         "any flight-recorder dumps as JSON (render dumps "
                         "with python -m repro.launch.report --flight)")
    args = ap.parse_args()
    telemetry = None
    if args.metrics_out or args.trace_out or args.watermark \
            or args.attrib_out:
        from repro.obs import FlightRecorder, RunTelemetry
        flight = FlightRecorder(watermark=args.watermark) \
            if args.watermark else None
        telemetry = RunTelemetry.create(
            engine=args.engine, offload=args.offload,
            memory_policy=args.memory_policy, flight=flight)

    cfg = dataclasses.replace(
        get_config("llama3_2_3b").smoke(), num_layers=args.layers,
        d_model=args.d_model, d_ff=2 * args.d_model, vocab_size=64,
        num_heads=4, num_kv_heads=2, head_dim=args.d_model // 4,
        mtp_depth=args.spec_k if args.spec_decode else 0)
    buckets = tuple(int(b) for b in args.capture_buckets.split(",")) \
        if args.capture_buckets else None
    lr = args.lr or (3e-2 if args.engine == "hydra" else 3e-3)
    rl = RLHFConfig(prompt_len=8, gen_len=16, lr=lr, critic_lr=lr,
                    kl_coef=0.0, top_k=0, engine=args.engine,
                    lora_rank=args.lora_rank,
                    memory_policy=args.memory_policy,
                    offload=args.offload, spec_decode=args.spec_decode,
                    spec_k=args.spec_k, capture_buckets=buckets)
    shard = None
    if args.ndp > 1 or args.ntp > 1:
        from repro.sharding import ShardedContext, validate_tp
        validate_tp(cfg, args.ntp)   # eager: clear error, not an XLA shape one
        need = args.ndp * args.ntp
        assert len(jax.devices()) >= need, \
            f"--ndp {args.ndp} --ntp {args.ntp} needs {need} local devices; " \
            f"run under XLA_FLAGS=--xla_force_host_platform_device_count={need}"
        shard = ShardedContext.create(args.ndp, zero_stage=args.zero_stage,
                                      model=args.ntp)
        print(f"mesh-sharded: ndp={args.ndp} ntp={args.ntp} "
              f"zero_stage={args.zero_stage}")
    trainer = RLHFTrainer(cfg, cfg, rl, jax.random.PRNGKey(0),
                          reward_fn=make_target_token_reward(7), shard=shard,
                          telemetry=telemetry)
    if shard is not None:
        print(f"per-device persistent state: "
              f"{trainer.per_device_state_bytes()/2**20:.2f} MiB")
    if args.engine == "hydra":
        eng = trainer.engine
        print(f"hydra engine: trunk {eng.base_param_count():,} params "
              f"(frozen), actor adapter "
              f"{eng.adapter_param_count('actor'):,} "
              f"({100 * eng.trainable_fraction('actor'):.1f}% trainable), "
              f"rank {args.lora_rank}")
    print(f"live after init: {live_device_bytes()/2**20:.2f} MiB "
          f"({args.engine})")

    key = jax.random.PRNGKey(1)
    t0 = time.time()
    for step in range(args.steps):
        k1, k2, key = jax.random.split(key, 3)
        prompts = jax.random.randint(k1, (args.batch, rl.prompt_len), 0,
                                     cfg.vocab_size)
        m = trainer.train_step(prompts, k2)
        if step % 10 == 0 or step == args.steps - 1:
            print(f"step {step:4d} reward {m['mean_reward']:+.4f} "
                  f"kl {m['kl']:.4f} clip {m['clip_frac']:.3f} "
                  f"vf {m['vf_loss']:.4f} ({time.time()-t0:.0f}s)")

    # per-phase live-memory report (the paper's profiler, on the real run;
    # hydra iterations add a mid-rollout sample record -> 8 per iteration)
    recs = trainer.memory.records[-(8 if args.engine == "hydra" else 7):]
    print("\nlast-iteration phase memory (policy="
          f"{args.memory_policy}, engine={args.engine}, "
          f"offload={args.offload}):")
    for r in recs:
        print(f"  {r['phase']:16s} {r['kind']:10s} "
              f"{r['live_bytes']/2**20:8.2f} MiB live "
              f"{r['host_bytes']/2**20:8.2f} MiB host")
    if args.ckpt_dir:
        params = (trainer.actor_state["params"] if args.engine == "separate"
                  else {"base": trainer.base_params,
                        "actor_adapter": trainer.actor_state["params"]})
        print("saved:", save(args.ckpt_dir, args.steps, params))
    if telemetry is not None:
        telemetry.write(args.metrics_out or None, args.trace_out or None)
        for p in (args.metrics_out, args.trace_out):
            if p:
                print("telemetry:", p)
    if args.attrib_out and telemetry is not None \
            and telemetry.attribution is not None:
        import json
        phases = {}
        for sp in telemetry.tracer.spans:
            if sp.cat == "phase" and "attrib" in sp.args:
                phases[sp.name] = {
                    "owners": sp.args["attrib"],
                    "unattributed": sp.args["attrib_unattributed"],
                    "measured_bytes": sp.args["measured_bytes"],
                    "sim_delta": sp.args.get("attrib_sim_delta")}
        fl = telemetry.flight
        bundle = {"schema": "attribution/v1", "engine": args.engine,
                  "offload": args.offload,
                  "final": telemetry.attribution.snapshot().to_record(),
                  "phases": phases,
                  "flight_dumps": list(fl.dumps) if fl is not None else []}
        with open(args.attrib_out, "w") as f:
            json.dump(bundle, f, indent=1, default=str)
        print("attribution:", args.attrib_out)


if __name__ == "__main__":
    main()
